"""Multi-link max-min engine vs an independent oracle, plus fabric axes.

The differential contract: max-min fair allocation is *unique*, so the
engine's link-perspective progressive filling (``maxmin_rates`` /
``NetworkEngine._run_maxmin``) and the flow-perspective water-fill in
``tests/_reference_fabric.py`` — written from scratch, no shared code —
must agree within 1e-9 on every randomized instance.  The seeded
``random.Random`` loops below run everywhere (they are the tier-1 gate:
200+ cases each); the ``@given`` variants add hypothesis shrinking where
it is installed.

Path-length-<=1 flows must be *bitwise* the single-resource engine: the
dispatch normalizes them into ``link`` and runs the original code, so
those cases are pitted against the frozen seed loop in
``tests/_reference_engine.py`` with plain ``==``.
"""
from __future__ import annotations

import random

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from _reference_engine import run_reference_flows
from _reference_fabric import reference_maxmin, run_reference_fabric_flows
from repro.core.events import (FlowBatch, FlowSpec, maxmin_rates, run_flows,
                               run_flow_batch)

# exact binary fractions: keeps randomized instances free of decimal
# rounding noise without making any tie easier (tie handling must agree
# structurally, and does — both loops recompute rates at every
# membership change)
_GRID = [k / 64.0 for k in range(1, 129)]

LINKS = ("nic", "up0", "up1", "spine")


def _rand_caps(rng: random.Random) -> dict:
    return {nm: rng.choice(_GRID) * 2.0 for nm in LINKS}


def _rand_demands(rng: random.Random, n: int) -> list:
    out = []
    for _ in range(n):
        links = rng.sample(LINKS, rng.randint(1, 3))
        out.append({nm: float(rng.randint(1, 3)) for nm in links})
    return out


def _rand_flows(rng: random.Random, multi_link: bool = True) -> list:
    """A randomized multi-job flow set over the LINKS pool.

    ``multi_link=True`` guarantees at least one path of length >= 2 (the
    max-min dispatch); ``False`` caps every path at one link (the
    bitwise-compatibility dispatch).
    """
    flows = []
    n_jobs = rng.randint(1, 4)
    op = 0
    for j in range(n_jobs):
        for _ in range(rng.randint(1, 4)):
            if multi_link:
                k = rng.randint(1, 3)
                path = tuple(rng.choice(LINKS) for _ in range(k))
            else:
                path = (rng.choice(LINKS),) if rng.random() < 0.5 else ()
            hold = rng.random() < 0.3
            work = rng.choice(_GRID)
            latency = rng.choice(_GRID) / 8.0
            flows.append(FlowSpec(
                op_id=op, ready=rng.choice(_GRID) * 2.0, work=work,
                latency=latency, priority=float(rng.randint(0, 2)),
                job=f"job{j}", link=rng.choice(LINKS), hold=hold,
                duration=work + latency if hold else None,
                worker=op % 4, path=path))
            op += 1
    if multi_link and not any(len(f.path) > 1 for f in flows):
        f = flows[0]
        flows[0] = f._replace(path=(LINKS[0], LINKS[1]))
    return flows


def _close(a, b, tol=1e-9):
    return abs(a - b) <= tol * max(1.0, abs(a), abs(b))


def _assert_results_close(got, want, tag=""):
    assert len(got) == len(want), tag
    for g, w in zip(got, want):
        assert g.op_id == w.op_id and g.job == w.job, (tag, g, w)
        assert g.contended == w.contended, (tag, g, w)
        for field in ("start", "wire_end", "end"):
            assert _close(getattr(g, field), getattr(w, field)), (tag, g, w)


# ---------------------------------------------------------------------------
# the rate solver vs the oracle (pure allocation, no event loop)
# ---------------------------------------------------------------------------

def test_maxmin_rates_matches_oracle_randomized():
    """>= 300 randomized allocation instances: engine vs oracle to 1e-9."""
    rng = random.Random(0xFAB)
    for case in range(300):
        caps = _rand_caps(rng)
        demands = _rand_demands(rng, rng.randint(1, 8))
        got = maxmin_rates(demands, caps)
        want = reference_maxmin(demands, caps)
        assert len(got) == len(want)
        for g, w in zip(got, want):
            assert _close(g, w), (case, demands, caps, got, want)


def test_maxmin_rates_known_instances():
    # solo flow through a 2:1-oversubscribed uplink (multiplicity 4, cap 2)
    assert maxmin_rates([{"nic": 1.0, "up": 4.0}], {"up": 2.0}) == [0.5]
    # three flows on one unit link: equal thirds
    for r in maxmin_rates([{"l": 1.0}] * 3, {}):
        assert _close(r, 1.0 / 3.0)
    # heterogeneous: the two-link flow freezes first, the other mops up
    rates = maxmin_rates([{"a": 1.0, "b": 3.0}, {"a": 1.0}], {"b": 0.75})
    assert _close(rates[0], 0.25) and _close(rates[1], 0.75)
    # nothing binds: everyone runs at the full-rate cap
    assert maxmin_rates([{"a": 1.0}, {"b": 1.0}], {"a": 5.0, "b": 5.0}) \
        == [1.0, 1.0]


def test_maxmin_rates_conservation_and_fairness_randomized():
    """Structural max-min properties on every randomized instance: no link
    over capacity, and no flow could rise without a saturated link (each
    rate below the cap is pinned by some link within tolerance)."""
    rng = random.Random(0xCAFE)
    for _ in range(200):
        caps = _rand_caps(rng)
        demands = _rand_demands(rng, rng.randint(1, 8))
        rates = maxmin_rates(demands, caps)
        used = {}
        for d, r in zip(demands, rates):
            assert 0.0 <= r <= 1.0
            for nm, m in d.items():
                used[nm] = used.get(nm, 0.0) + m * r
        for nm, u in used.items():
            assert u <= caps[nm] * (1.0 + 1e-9) + 1e-12
        for d, r in zip(demands, rates):
            if r >= 1.0 - 1e-12:
                continue   # at the per-flow cap: allowed to leave slack
            saturated = any(used[nm] >= caps[nm] * (1.0 - 1e-9) - 1e-12
                            for nm in d)
            assert saturated, (d, r, used, caps)


# ---------------------------------------------------------------------------
# the event loop vs the oracle loop (>= 200 randomized flow sets)
# ---------------------------------------------------------------------------

def test_engine_matches_fabric_oracle_randomized():
    """>= 200 randomized multi-link flow sets: the engine's max-min event
    loop agrees with the independent O(n^2) oracle to 1e-9 on every
    start / wire_end / end, with identical contended flags."""
    rng = random.Random(0xD1FF)
    for case in range(200):
        caps = _rand_caps(rng)
        flows = _rand_flows(rng, multi_link=True)
        got = run_flows(flows, capacities=caps)
        want = run_reference_fabric_flows(flows, caps)
        _assert_results_close(got, want, case)


def test_engine_batch_path_matches_fabric_oracle():
    """The columnar entry point routes multi-link batches through the
    same max-min loop: results match the oracle too."""
    rng = random.Random(0xBA7C)
    for case in range(30):
        caps = _rand_caps(rng)
        flows = _rand_flows(rng, multi_link=True)
        rb = run_flow_batch(FlowBatch.from_flows(flows), capacities=caps)
        want = run_reference_fabric_flows(flows, caps)
        for i, w in enumerate(want):
            assert _close(rb.start[i], w.start), case
            assert _close(rb.wire_end[i], w.wire_end), case
            assert _close(rb.end[i], w.end), case
            assert bool(rb.contended[i]) == w.contended, case


def test_path_length_one_bitwise_vs_pathless_engine():
    """Flows whose paths all have length <= 1 must run the original
    single-resource engine *bit-for-bit*: the dispatch normalizes
    one-element paths into ``link`` and never enters the max-min loop,
    so results equal a run that never heard of paths, with plain ``==``
    (200 randomized cases; empty paths mean ``link``)."""
    rng = random.Random(0x5EED)
    for case in range(200):
        caps = {nm: rng.choice(_GRID) * 2.0 for nm in LINKS}
        flows = _rand_flows(rng, multi_link=False)
        got = run_flows(flows, capacities=caps)
        pathless = [f._replace(link=f.path[0], path=()) if f.path else f
                    for f in flows]
        assert got == run_flows(pathless, capacities=caps), case


def test_path_length_one_matches_seed_reference_engine():
    """...and those same normalized runs agree with the frozen seed loop
    in tests/_reference_engine.py under its established contract: 1e-9
    relative on all times, bit-exact closed forms when uncontended (the
    seed engine re-derives contended completions stepwise, so contended
    multi-job times match to tolerance, not bits — the contract
    test_events_equivalence.py pins for the pathless engine)."""
    rng = random.Random(0xC0DE)
    for case in range(200):
        caps = {nm: rng.choice(_GRID) * 2.0 for nm in LINKS}
        flows = _rand_flows(rng, multi_link=False)
        pathless = [f._replace(link=f.path[0], path=()) if f.path else f
                    for f in flows]
        got = run_flows(flows, capacities=caps)
        want = run_reference_flows(pathless, caps, max_iters_factor=200)
        for g, w in zip(got, want):
            assert g.op_id == w.op_id and g.contended == w.contended, case
            for field in ("start", "wire_end", "end"):
                assert _close(getattr(g, field), getattr(w, field)), \
                    (case, g, w)
        if not any(g.contended for g in got):
            assert got == want, case  # all-closed-form runs: bit-identical


# ---------------------------------------------------------------------------
# hypothesis variants (shrinking where installed; skipped otherwise)
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=50, deadline=None)
def test_hypothesis_maxmin_matches_oracle(seed):
    rng = random.Random(seed)
    caps = _rand_caps(rng)
    demands = _rand_demands(rng, rng.randint(1, 8))
    got = maxmin_rates(demands, caps)
    want = reference_maxmin(demands, caps)
    for g, w in zip(got, want):
        assert _close(g, w), (seed, demands, caps)


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=30, deadline=None)
def test_hypothesis_engine_matches_fabric_oracle(seed):
    rng = random.Random(seed)
    caps = _rand_caps(rng)
    flows = _rand_flows(rng, multi_link=True)
    _assert_results_close(run_flows(flows, capacities=caps),
                          run_reference_fabric_flows(flows, caps), seed)


# ---------------------------------------------------------------------------
# fluid-model properties
# ---------------------------------------------------------------------------

def test_doubling_capacities_halves_completion_times():
    """With every capacity <= 0.5 (so the per-flow 1.0 cap never binds,
    even doubled), ready=0 and latency=0, the fluid solve is positively
    homogeneous: doubling all capacities exactly halves every wire end."""
    rng = random.Random(0x2F)
    for case in range(60):
        caps = {nm: rng.choice(_GRID) / 4.0 for nm in LINKS}  # <= 0.5
        flows = []
        for j in range(rng.randint(1, 4)):
            for k in range(rng.randint(1, 3)):
                path = tuple(rng.choice(LINKS)
                             for _ in range(rng.randint(1, 3)))
                flows.append(FlowSpec(
                    op_id=len(flows), ready=0.0, work=rng.choice(_GRID),
                    job=f"job{j}", path=path))
        base = run_flows(flows, capacities=caps)
        fast = run_flows(flows,
                         capacities={nm: 2.0 * c for nm, c in caps.items()})
        for b, f in zip(base, fast):
            assert _close(b.wire_end, 2.0 * f.wire_end), (case, b, f)


def test_adding_a_flow_never_speeds_up_existing_flows():
    """Work conservation on a shared route: a new competitor on the same
    path can only slow others down — every pre-existing flow's wire end
    is monotone non-decreasing.  The property is deliberately scoped to
    a common path: max-min is *non-monotone* across different paths (an
    intruder that shifts a multi-link flow's bottleneck frees capacity
    on its other links, speeding up third parties), and ready times are
    all 0 so each job's service order is fixed (a delayed admission
    under ready gating can pick a different flow first, and reordering
    legitimately breaks per-op monotonicity)."""
    rng = random.Random(0xADD)
    for case in range(60):
        caps = _rand_caps(rng)
        path = tuple(rng.choice(LINKS) for _ in range(rng.randint(2, 4)))
        flows = [f._replace(ready=0.0, path=path)
                 for f in _rand_flows(rng, multi_link=True)]
        base = run_flows(flows, capacities=caps)
        extra = FlowSpec(op_id=len(flows), ready=0.0,
                         work=rng.choice(_GRID) * 2.0, job="intruder",
                         path=path)
        more = run_flows(flows + [extra], capacities=caps)
        for b, m in zip(base, more):
            assert m.wire_end >= b.wire_end - 1e-9, (case, b, m)


def test_oversubscribed_solo_flow_runs_at_uplink_share():
    """One flow, path nic + 4x uplink of capacity 2: rate 1/2, so unit
    work takes 2 seconds, flagged contended (no closed form applies)."""
    [r] = run_flows([FlowSpec(op_id=0, ready=0.0, work=1.0,
                              path=("nic", "up", "up", "up", "up"))],
                    capacities={"up": 2.0})
    assert r.contended and _close(r.wire_end, 2.0)
    # two such jobs split the uplink: each at 1/4, 4 seconds
    two = run_flows([FlowSpec(op_id=i, ready=0.0, work=1.0, job=f"j{i}",
                              path=("nic", "up", "up", "up", "up"))
                     for i in range(2)], capacities={"up": 2.0})
    for r in two:
        assert _close(r.wire_end, 4.0)


def test_rails_and_paths_are_mutually_exclusive():
    flows = [FlowSpec(op_id=0, ready=0.0, work=1.0, path=("a", "b"))]
    with pytest.raises(ValueError):
        run_flows(flows, rails={"nic": 2})


# ---------------------------------------------------------------------------
# batch plumbing: with_path, relabel aliasing, roundtrips
# ---------------------------------------------------------------------------

def _path_batch():
    flows = [FlowSpec(op_id=i, ready=0.1 * i, work=0.5, job="j",
                      path=("nic", "up0", "up0"))
             for i in range(4)]
    return FlowBatch.from_flows(flows), flows


def test_batch_path_roundtrip():
    batch, flows = _path_batch()
    assert batch.to_flows() == flows
    again = FlowBatch.from_flows(batch.to_flows())
    assert again.links == batch.links
    assert (again.path_off == batch.path_off).all()
    assert (again.path_link == batch.path_link).all()


def test_with_path_stamps_uniform_route():
    flows = [FlowSpec(op_id=i, ready=0.0, work=1.0) for i in range(3)]
    batch = FlowBatch.from_flows(flows).with_path(("nic", "up0", "up0"))
    assert all(f.path == ("nic", "up0", "up0") for f in batch.to_flows())
    # clearing the route drops the CSR columns entirely
    cleared = batch.with_path(())
    assert cleared.path_off is None and cleared.path_link is None


def test_relabel_path_columns_never_alias_the_source():
    """Regression: relabel deep-copies the path CSR — mutating the
    relabeled batch's path columns must never leak into the source (and
    vice versa)."""
    batch, _ = _path_batch()
    rel = batch.relabel(100, "jobX")
    assert rel.path_off is not batch.path_off
    assert rel.path_link is not batch.path_link
    orig_link = batch.path_link.copy()
    orig_off = batch.path_off.copy()
    rel.path_link[:] = 0
    rel.path_off[:] = 0
    assert (batch.path_link == orig_link).all()
    assert (batch.path_off == orig_off).all()
    # and the relabeled batch still round-trips with its own values
    batch.path_link[:] = 0
    rel2 = batch.relabel(200, "jobY")
    assert (rel2.path_link == 0).all()


def test_concat_batches_remaps_path_codes():
    from repro.core.events import concat_batches
    a_flows = [FlowSpec(op_id=0, ready=0.0, work=1.0, job="a",
                        path=("nic", "upA"))]
    b_flows = [FlowSpec(op_id=1, ready=0.0, work=1.0, job="b",
                        path=("upB", "nic"))]
    merged = concat_batches([FlowBatch.from_flows(a_flows),
                             FlowBatch.from_flows(b_flows)])
    assert merged.to_flows() == a_flows + b_flows
    # a pathless batch concatenated with a pathed one keeps empty routes
    c_flows = [FlowSpec(op_id=2, ready=0.0, work=1.0, job="c")]
    both = concat_batches([FlowBatch.from_flows(c_flows),
                           FlowBatch.from_flows(a_flows)])
    assert both.to_flows() == c_flows + a_flows


# ---------------------------------------------------------------------------
# fabric lowering: simulate-level contracts
# ---------------------------------------------------------------------------

def _fab_sim(**kw):
    from repro.core.simulator import simulate
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    return simulate(from_cnn("resnet50"), n_workers=16,
                    bandwidth=10.0 * GBPS, transport="ideal", **kw)


@pytest.mark.parametrize("topology", ["ring", "tree", "hierarchical"])
def test_fabric_1to1_bitwise_flat(topology):
    """The elision contract end to end: a 1:1 Clos fabric's uplink can
    never bind, the path collapses to the NIC, and the result is byte-
    for-byte the flat topology's."""
    base = _fab_sim(topology=topology)
    assert _fab_sim(topology=topology, fabric="clos",
                    oversubscription=1.0) == base
    assert _fab_sim(topology=topology) == base  # kwargs left no residue


def test_fabric_oversubscription_prices_striped_collectives():
    ring1 = _fab_sim(topology="ring", fabric="clos", oversubscription=1.0)
    ring4 = _fab_sim(topology="ring", fabric="clos", oversubscription=4.0)
    hier4 = _fab_sim(topology="hierarchical", fabric="clos",
                     oversubscription=4.0)
    assert ring4.t_sync > ring1.t_sync          # striped ring pays 4x
    # rack-local reduction keeps the leader's uplink demand at 1 <= cap:
    # hierarchical rides out 4:1 entirely (elided path, flat bits)
    assert hier4 == _fab_sim(topology="hierarchical")


def test_fabric_none_rejects_oversubscription():
    with pytest.raises(ValueError):
        _fab_sim(fabric="none", oversubscription=2.0)
    from repro.core.fabric import resolve_fabric
    with pytest.raises(ValueError):
        resolve_fabric("torus")


def test_fabric_conflicts_with_multirail():
    with pytest.raises(ValueError):
        _fab_sim(topology="ring", fabric="clos", oversubscription=4.0,
                 n_rails=2)


def test_fabric_contention_shares_the_uplink():
    """Two co-scheduled jobs on a 4:1 fabric split the uplink: each is
    strictly slower than running the fabric alone, and the contended pair
    is deterministic."""
    from repro.core.simulator import simulate_contention
    from repro.core.timeline import from_cnn
    from repro.core.transport import GBPS
    tls = [from_cnn("resnet50")] * 2
    kw = dict(n_workers=16, bandwidth=10.0 * GBPS, transport="ideal")
    solo = _fab_sim(topology="ring", fabric="clos", oversubscription=4.0)
    pair = simulate_contention(tls, fabric="clos", oversubscription=4.0,
                               **kw)
    again = simulate_contention(tls, fabric="clos", oversubscription=4.0,
                                **kw)
    assert pair == again
    assert all(r.t_sync > solo.t_sync for r in pair)
    # 1:1 contention degenerates to the flat shared link, bitwise
    assert simulate_contention(tls, fabric="clos", oversubscription=1.0,
                               **kw) == simulate_contention(tls, **kw)


def test_tree_topology_priced_and_bandwidth_poor():
    """The binomial tree moves 2*ceil(log2 n)*S bytes per worker — far
    worse than the ring's 2S(n-1)/n at scale — and rides the same fabric
    lowering as the ring (striped: full uplink multiplicity)."""
    from repro.core.network_model import TreeAllReduce, make_cost_model
    from repro.core.addest import AddEst
    cost = make_cost_model(16, 1e9, AddEst.v100(), topology="tree")
    assert isinstance(cost, TreeAllReduce)
    ring = make_cost_model(16, 1e9, AddEst.v100(), topology="ring")
    assert cost.wire_time(1e8) > ring.wire_time(1e8)
    tree = _fab_sim(topology="tree")
    assert tree.t_sync > _fab_sim(topology="ring").t_sync
    assert _fab_sim(topology="tree", fabric="clos",
                    oversubscription=4.0).t_sync > tree.t_sync


# ---------------------------------------------------------------------------
# experiments: fabric axes elided at default, grid registered and gated
# ---------------------------------------------------------------------------

def test_fabric_axes_elided_at_default():
    from repro.experiments import GRIDS, Cell, ExperimentSpec
    solo = Cell("resnet50", 2, 10.0, "ideal", 1.0, "ring")
    for key in ("fabric", "oversubscription"):
        assert key not in solo.to_dict()
    assert Cell.from_dict(solo.to_dict()) == solo
    fab = Cell("resnet50", 2, 10.0, "ideal", 1.0, "ring",
               fabric="clos", oversubscription=4.0)
    d = fab.to_dict()
    assert d["fabric"] == "clos" and d["oversubscription"] == 4.0
    assert Cell.from_dict(d) == fab

    plain = ExperimentSpec(name="t")
    for key in ("fabric", "oversubscription"):
        assert key not in plain.to_dict()
    swept = ExperimentSpec(name="t", fabric=("clos",),
                           oversubscription=(1.0, 4.0))
    assert swept.spec_hash() != plain.spec_hash()
    assert ExperimentSpec.from_dict(swept.to_dict()) == swept
    assert "fabric" not in GRIDS["paper-fig1"].canonical_json()


def test_fabric_grid_registered_and_gated():
    from repro.experiments import GRIDS, grids
    from repro.experiments.validations import VALIDATORS
    spec = GRIDS["fabric"]
    assert spec.name in VALIDATORS, "gated grid must carry claim checks"
    assert grids.resolve("fabric")[0] is spec
    assert set(spec.topology) == {"ring", "tree", "hierarchical"}
    assert spec.fabric == ("clos",)
    assert 1.0 in spec.oversubscription and max(spec.oversubscription) > 1.0


def test_fabric_grid_validations_pass():
    """Run a reduced fabric grid end to end and check the full validator
    suite holds (the golden artifact gates the full grid in CI)."""
    import dataclasses

    from repro.experiments import GRIDS, run_spec
    from repro.experiments.validations import _fabric
    spec = dataclasses.replace(GRIDS["fabric"], models=("resnet50",),
                               bandwidth_gbps=(10.0,))
    rec = run_spec(spec, executor="serial")
    checks = _fabric(rec["cells"])
    assert all(checks.values()), checks


def test_fig15_fabric_whatif_rows():
    from repro.core.whatif import fig15_fabric_oversubscription
    rows = fig15_fabric_oversubscription(models=("resnet50",), bws=(10.0,),
                                         topologies=("ring", "hierarchical"))
    by = {r["topology"]: r for r in rows}
    assert _close(by["ring"]["oversub1_retention"], 1.0)
    assert by["ring"]["oversub4_retention"] < 0.5
    assert _close(by["hierarchical"]["oversub4_retention"], 1.0)
