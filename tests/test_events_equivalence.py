"""Heap engine vs the retained seed engine (tests/_reference_engine.py).

Property tests pit ``repro.core.events.NetworkEngine`` against the seed
loop on randomized flow sets — multi-job, fractional link capacities,
``hold`` vs pipelined, duplicate ready times — plus the closed-form fifo
fast path against the engine, and the progress-based stall detector.

Equivalence contract (documented in ``events.py``):

- all times (start, wire_end, end) agree within 1e-9 relative; uncontended
  and ``hold`` flows agree *bit-for-bit* (both engines use the same closed
  forms there);
- ``contended`` flags agree except on zero-duration overlaps, where the
  seed flagged flows co-admitted at an instant one of them already
  completes; the heap engine only counts sharing of nonzero duration, so
  ``new.contended`` implies ``ref.contended`` but not conversely.  The
  generators below avoid manufacturing exact-tie cases (continuous values),
  so flags are compared for equality.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from _reference_engine import run_reference_flows

from repro.core.events import FlowSpec, run_flows
from repro.core.schedule import lower_buckets, plan_to_flows


def _random_flows(n, n_jobs, n_links, seed, hold_p=0.35, dup_ready=False):
    rng = np.random.default_rng(seed)
    ready = rng.uniform(0.0, 1.0, n)
    if dup_ready:
        # duplicate ready times: bursts of flows released at one instant
        pool = rng.uniform(0.0, 1.0, max(1, n // 4))
        ready = rng.choice(pool, n)
    flows = []
    for i in range(n):
        work = float(rng.choice([rng.uniform(1e-6, 2.0),
                                 rng.uniform(1e-12, 1e-7)]))
        lat = float(rng.choice([0.0, rng.uniform(0.0, 0.5)]))
        hold = bool(rng.random() < hold_p)
        flows.append(FlowSpec(
            op_id=i, ready=float(ready[i]), work=work, latency=lat,
            priority=float(rng.choice([0.0, float(rng.integers(0, 5)), -1.0])),
            job=f"j{rng.integers(0, n_jobs)}",
            link=f"l{rng.integers(0, n_links)}",
            hold=hold, duration=work + lat if hold else None))
    return flows


def _assert_equivalent(flows, capacities=None, *, exact=False):
    try:
        ref = run_reference_flows(flows, capacities, max_iters_factor=200)
    except RuntimeError:
        pytest.skip("seed engine did not converge on this input")
    new = run_flows(flows, capacities)
    assert len(ref) == len(new) == len(flows)
    for a, b in zip(ref, new):
        assert a.op_id == b.op_id and a.job == b.job
        if exact:
            assert a.start == b.start
            assert a.wire_end == b.wire_end
            assert a.end == b.end
        else:
            scale = max(abs(a.end), abs(b.end), 1e-9)
            assert abs(a.start - b.start) <= 1e-9 * scale + 1e-15
            assert abs(a.wire_end - b.wire_end) <= 1e-9 * scale + 1e-15
            assert abs(a.end - b.end) <= 1e-9 * scale + 1e-15
        assert a.contended == b.contended
    return ref, new


# ---------------------------------------------------------------------------
# randomized equivalence (satellite: property tests vs the seed engine)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 80), n_jobs=st.integers(1, 6),
       n_links=st.integers(1, 3), seed=st.integers(0, 10_000))
def test_multi_job_equivalence(n, n_jobs, n_links, seed):
    _assert_equivalent(_random_flows(n, n_jobs, n_links, seed))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 60), n_jobs=st.integers(2, 6),
       seed=st.integers(0, 10_000),
       cap=st.sampled_from([0.25, 0.5, 0.75, 2.0, 4.0]))
def test_fractional_and_multi_capacity_links(n, n_jobs, seed, cap):
    flows = _random_flows(n, n_jobs, 2, seed)
    _assert_equivalent(flows, {"l0": cap, "l1": 1.0})


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 60), n_jobs=st.integers(1, 5),
       seed=st.integers(0, 10_000))
def test_duplicate_ready_times(n, n_jobs, seed):
    _assert_equivalent(_random_flows(n, n_jobs, 2, seed, dup_ready=True))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 60), seed=st.integers(0, 10_000),
       hold_all=st.booleans())
def test_hold_vs_pipelined_single_job_bit_exact(n, seed, hold_all):
    """A single job never contends, so both engines take their closed
    forms and must agree bit-for-bit — hold (fifo) and pipelined alike."""
    flows = _random_flows(n, 1, 1, seed, hold_p=1.0 if hold_all else 0.0)
    ref, new = _assert_equivalent(flows, exact=True)
    assert not any(r.contended for r in new)


def test_known_seeds_cover_contention():
    """Deterministic smoke: the random generator does produce contended
    multi-job runs (the property above is not vacuously closed-form)."""
    flows = _random_flows(60, 4, 1, seed=7, hold_p=0.0)
    _, new = _assert_equivalent(flows)
    assert any(r.contended for r in new)


# ---------------------------------------------------------------------------
# the closed-form fifo fast path vs the engine (bit-exact dispatch)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(24, 120), seed=st.integers(0, 10_000))
def test_fifo_fast_path_bit_exact_vs_engine(n, seed):
    from repro.core.simulator import _fifo_fast_results
    rng = np.random.default_rng(seed)
    ready = np.sort(rng.uniform(0, 0.5, n))
    buckets = [(float(r), float(rng.uniform(1e3, 1e8)), 1) for r in ready]
    plan = lower_buckets(buckets, scheduler="fifo")

    class _Cost:
        def time(self, size):
            return size / 1e9 + 1e-4

        def wire_time(self, size):
            return size / 1e9

    flows = plan_to_flows(plan, _Cost(), 1e-6)
    fast = _fifo_fast_results(plan, flows)
    assert fast is not None, "eligible fifo plan must dispatch"
    slow = run_flows(flows)
    for a, b in zip(fast, slow):
        assert a.start == b.start
        assert a.wire_end == b.wire_end
        assert a.end == b.end
        assert not a.contended and not b.contended


def test_fast_path_dispatch_is_checked_not_assumed():
    from repro.core.simulator import _fifo_fast_results
    buckets = [(0.001 * i, 1e6, 1) for i in range(30)]
    fifo = lower_buckets(buckets, scheduler="fifo")

    class _Cost:
        def time(self, size):
            return size / 1e9

    flows = plan_to_flows(fifo, _Cost(), 0.0)
    assert _fifo_fast_results(fifo, flows) is not None
    # non-fifo plans never dispatch
    chunked = lower_buckets(buckets, scheduler="chunked", n_chunks=2)
    cflows = plan_to_flows(chunked, _Cost(), 0.0)
    assert _fifo_fast_results(chunked, cflows) is None
    # a flow that regresses the ready order invalidates the closed form
    bad = list(flows)
    bad[10] = bad[10]._replace(ready=0.5)
    assert _fifo_fast_results(fifo, bad) is None
    # as does a second job or a second link sneaking in
    bad = list(flows)
    bad[3] = bad[3]._replace(job="other")
    assert _fifo_fast_results(fifo, bad) is None
    bad = list(flows)
    bad[3] = bad[3]._replace(link="nic1")
    assert _fifo_fast_results(fifo, bad) is None
    # small plans stay on the engine (numpy overhead exceeds the calendar)
    small = lower_buckets(buckets[:4], scheduler="fifo")
    sflows = plan_to_flows(small, _Cost(), 0.0)
    assert _fifo_fast_results(small, sflows) is None


def test_serialized_closed_form_matches_python_loop():
    from repro.core.simulator import _serialized_closed_form
    rng = np.random.default_rng(123)
    for _ in range(50):
        n = int(rng.integers(1, 200))
        ready = np.sort(rng.uniform(0, 1.0, n))
        dur = rng.uniform(1e-6, 0.1, n) * 10.0 ** rng.integers(-3, 2)
        starts, ends = _serialized_closed_form(ready, dur)
        prev = 0.0
        for i in range(n):
            s = ready[i] if ready[i] > prev else prev
            e = s + dur[i]
            assert starts[i] == s         # bit-exact, not approx
            assert ends[i] == e
            prev = e


# ---------------------------------------------------------------------------
# stall detection (satellite bugfix: no iteration-count heuristic)
# ---------------------------------------------------------------------------

def test_heavily_contended_multi_job_completes():
    """The seed's ``10 * n + 100`` convergence heuristic was a guess; the
    heap engine must finish any valid plan, however contended — here 8 jobs
    x 32-chunk plans with duplicate ready bursts on one link."""
    flows = []
    base = 0
    for j in range(8):
        for b in range(18):
            for c in range(32):
                flows.append(FlowSpec(
                    op_id=base, ready=0.01 * b, work=1e-4, latency=1e-5,
                    priority=float(b), job=f"job{j}"))
                base += 1
    res = run_flows(flows)
    assert len(res) == len(flows)
    assert all(r.end >= r.start for r in res)


def test_zero_work_flows_terminate():
    flows = [FlowSpec(op_id=i, ready=0.0, work=0.0, job=f"j{i % 3}")
             for i in range(50)]
    res = run_flows(flows)
    assert len(res) == 50
    assert all(r.end == 0.0 for r in res)
