"""Heap engine vs the retained seed engine (tests/_reference_engine.py).

Property tests pit ``repro.core.events.NetworkEngine`` against the seed
loop on randomized flow sets — multi-job, fractional link capacities,
``hold`` vs pipelined, duplicate ready times, and priority-scheduled
(heap-mode) plans — plus the closed-form fifo fast path against the
engine, and the progress-based stall detector.

Equivalence contract (documented in ``events.py``):

- all times (start, wire_end, end) agree within 1e-9 relative; uncontended
  and ``hold`` flows agree *bit-for-bit* (both engines use the same closed
  forms there);
- the numpy bulk-commit path (pointer *and* heap mode) is **bit-identical**
  to the scalar event loop: disabling it via ``_BULK_MIN_ACTIVE`` must not
  change a single bit of any result;
- ``contended`` flags agree except on zero-duration overlaps, where the
  seed flagged flows co-admitted at an instant one of them already
  completes; the heap engine only counts sharing of nonzero duration, so
  ``new.contended`` implies ``ref.contended`` but not conversely.  The
  generators below avoid manufacturing exact-tie cases (continuous values),
  so flags are compared for equality.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from _reference_engine import run_reference_flows

from repro.core.events import FlowSpec, perturb_flows, run_flows
from repro.core.schedule import lower_buckets, plan_to_flows


def _random_flows(n, n_jobs, n_links, seed, hold_p=0.35, dup_ready=False):
    rng = np.random.default_rng(seed)
    ready = rng.uniform(0.0, 1.0, n)
    if dup_ready:
        # duplicate ready times: bursts of flows released at one instant
        pool = rng.uniform(0.0, 1.0, max(1, n // 4))
        ready = rng.choice(pool, n)
    flows = []
    for i in range(n):
        work = float(rng.choice([rng.uniform(1e-6, 2.0),
                                 rng.uniform(1e-12, 1e-7)]))
        lat = float(rng.choice([0.0, rng.uniform(0.0, 0.5)]))
        hold = bool(rng.random() < hold_p)
        flows.append(FlowSpec(
            op_id=i, ready=float(ready[i]), work=work, latency=lat,
            priority=float(rng.choice([0.0, float(rng.integers(0, 5)), -1.0])),
            job=f"j{rng.integers(0, n_jobs)}",
            link=f"l{rng.integers(0, n_links)}",
            hold=hold, duration=work + lat if hold else None))
    return flows


def _assert_equivalent(flows, capacities=None, *, exact=False):
    try:
        ref = run_reference_flows(flows, capacities, max_iters_factor=200)
    except RuntimeError:
        pytest.skip("seed engine did not converge on this input")
    new = run_flows(flows, capacities)
    assert len(ref) == len(new) == len(flows)
    for a, b in zip(ref, new):
        assert a.op_id == b.op_id and a.job == b.job
        if exact:
            assert a.start == b.start
            assert a.wire_end == b.wire_end
            assert a.end == b.end
        else:
            scale = max(abs(a.end), abs(b.end), 1e-9)
            assert abs(a.start - b.start) <= 1e-9 * scale + 1e-15
            assert abs(a.wire_end - b.wire_end) <= 1e-9 * scale + 1e-15
            assert abs(a.end - b.end) <= 1e-9 * scale + 1e-15
        assert a.contended == b.contended
    return ref, new


# ---------------------------------------------------------------------------
# randomized equivalence (satellite: property tests vs the seed engine)
# ---------------------------------------------------------------------------

# generator bounds deliberately straddle _SMALL_PLAN_MAX_FLOWS (64): both
# the plain-list small-plan setup and the columnar numpy/bulk-commit setup
# must face the randomized reference comparison

@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 160), n_jobs=st.integers(1, 6),
       n_links=st.integers(1, 3), seed=st.integers(0, 10_000))
def test_multi_job_equivalence(n, n_jobs, n_links, seed):
    _assert_equivalent(_random_flows(n, n_jobs, n_links, seed))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 120), n_jobs=st.integers(2, 6),
       seed=st.integers(0, 10_000),
       cap=st.sampled_from([0.25, 0.5, 0.75, 2.0, 4.0]))
def test_fractional_and_multi_capacity_links(n, n_jobs, seed, cap):
    flows = _random_flows(n, n_jobs, 2, seed)
    _assert_equivalent(flows, {"l0": cap, "l1": 1.0})


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 120), n_jobs=st.integers(1, 5),
       seed=st.integers(0, 10_000))
def test_duplicate_ready_times(n, n_jobs, seed):
    _assert_equivalent(_random_flows(n, n_jobs, 2, seed, dup_ready=True))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 120), seed=st.integers(0, 10_000),
       hold_all=st.booleans())
def test_hold_vs_pipelined_single_job_bit_exact(n, seed, hold_all):
    """A single job never contends, so both engines take their closed
    forms and must agree bit-for-bit — hold (fifo) and pipelined alike."""
    flows = _random_flows(n, 1, 1, seed, hold_p=1.0 if hold_all else 0.0)
    ref, new = _assert_equivalent(flows, exact=True)
    assert not any(r.contended for r in new)


def test_known_seeds_cover_contention():
    """Deterministic smoke: the random generator does produce contended
    multi-job runs (the property above is not vacuously closed-form)."""
    flows = _random_flows(60, 4, 1, seed=7, hold_p=0.0)
    _, new = _assert_equivalent(flows)
    assert any(r.contended for r in new)


# ---------------------------------------------------------------------------
# heap-mode bulk commit: priority plans vs the reference, and the
# bulk-vs-scalar bit-identity contract
# ---------------------------------------------------------------------------

class _LinearCost:
    """Deterministic toy cost model for plan lowering in tests."""

    def time(self, size):
        return size / 1e9 + 5e-5

    def wire_time(self, size):
        return size / 1e9


def _priority_plan_flows(n_jobs, n_buckets, n_chunks, seed, *, jitter=0.0,
                         dup_flush=False):
    """Contending jobs under the *priority* scheduler: every job's ready
    times regress along service order, so all jobs run heap-mode
    admission.  Chunks of one bucket share a priority (duplicates) and a
    flush time (equal ready bursts) by construction; ``dup_flush``
    additionally collapses flush times across buckets."""
    rng = np.random.default_rng(seed)
    flows, base = [], 0
    for j in range(n_jobs):
        ready = np.sort(rng.uniform(0.0, 0.05, n_buckets))
        if dup_flush:
            ready = np.repeat(ready[::2], 2)[:n_buckets]
        buckets = [(float(t), float(sz), 1) for t, sz in
                   zip(ready, rng.uniform(1e5, 5e7, n_buckets))]
        plan = lower_buckets(buckets, scheduler="priority",
                             n_chunks=n_chunks)
        fl = plan_to_flows(plan, _LinearCost(), 1e-6, job=f"j{j}",
                           op_id_base=base)
        if jitter > 0.0:
            fl = perturb_flows(fl, jitter, seed ^ 0x5A5A, stream=j)
        base += len(fl)
        flows.extend(fl)
    return flows


@settings(max_examples=20, deadline=None)
@given(n_jobs=st.integers(2, 6), n_buckets=st.integers(2, 8),
       n_chunks=st.integers(2, 16), seed=st.integers(0, 10_000),
       dup_flush=st.booleans())
def test_priority_plans_match_reference(n_jobs, n_buckets, n_chunks, seed,
                                        dup_flush):
    flows = _priority_plan_flows(n_jobs, n_buckets, n_chunks, seed,
                                 dup_flush=dup_flush)
    _assert_equivalent(flows)


@settings(max_examples=15, deadline=None)
@given(n_jobs=st.integers(2, 5), n_buckets=st.integers(2, 6),
       n_chunks=st.integers(2, 12), seed=st.integers(0, 10_000))
def test_priority_plans_with_jitter_match_reference(n_jobs, n_buckets,
                                                    n_chunks, seed):
    flows = _priority_plan_flows(n_jobs, n_buckets, n_chunks, seed,
                                 jitter=0.01)
    _assert_equivalent(flows)


@settings(max_examples=15, deadline=None)
@given(n_jobs=st.integers(2, 5), n_buckets=st.integers(2, 6),
       seed=st.integers(0, 10_000),
       cap=st.sampled_from([0.5, 0.75, 2.0]))
def test_priority_plans_fractional_capacity(n_jobs, n_buckets, seed, cap):
    flows = _priority_plan_flows(n_jobs, n_buckets, 8, seed)
    _assert_equivalent(flows, {"nic": cap})


@settings(max_examples=12, deadline=None)
@given(n_jobs=st.integers(2, 4), n_buckets=st.integers(2, 6),
       n_rails=st.integers(2, 3), seed=st.integers(0, 10_000))
def test_priority_plans_on_rails_match_reference(n_jobs, n_buckets,
                                                 n_rails, seed):
    """Heap-mode jobs on a multi-rail link: rails must still behave as
    independently named links when every lane runs priority admission."""
    rng = np.random.default_rng(seed ^ 0x77)
    flows = [f._replace(rail=int(rng.integers(0, n_rails)))
             for f in _priority_plan_flows(n_jobs, n_buckets, 8, seed)]
    try:
        ref = run_reference_flows(
            [f._replace(link=f"{f.link}#r{f.rail}") for f in flows],
            max_iters_factor=200)
    except RuntimeError:
        pytest.skip("seed engine did not converge on this input")
    new = run_flows(flows, rails={"nic": n_rails})
    for a, b in zip(ref, new):
        scale = max(abs(a.end), abs(b.end), 1e-9)
        assert abs(a.end - b.end) <= 1e-9 * scale + 1e-15
        assert a.contended == b.contended


def test_priority_plans_match_reference_known_seeds():
    """Deterministic twin of the property tests above (runs without
    hypothesis): contending priority plans — duplicate priorities and
    equal ready bursts by construction — against the seed engine, at
    sizes that exercise the columnar heap-mode setup and the bulk path,
    with and without jitter and fractional capacity."""
    cases = [
        dict(n_jobs=4, n_buckets=6, n_chunks=12, seed=2),
        dict(n_jobs=6, n_buckets=8, n_chunks=16, seed=13, dup_flush=True),
        dict(n_jobs=3, n_buckets=5, n_chunks=8, seed=99, jitter=0.01),
    ]
    for kw in cases:
        flows = _priority_plan_flows(**kw)
        assert len(flows) > 64      # columnar setup + bulk, not small-plan
        _, new = _assert_equivalent(flows)
        assert any(r.contended for r in new)
    flows = _priority_plan_flows(4, 6, 10, seed=21)
    _assert_equivalent(flows, {"nic": 0.5})


def _bulk_disabled(monkeypatch, flows, capacities=None):
    import repro.core.events as ev
    monkeypatch.setattr(ev, "_BULK_MIN_ACTIVE", 10**9)
    out = run_flows(flows, capacities)
    monkeypatch.undo()
    return out


@pytest.mark.parametrize("scheduler", ["chunked", "priority"])
def test_bulk_commit_bit_identical_to_scalar(monkeypatch, scheduler):
    """The acceptance contract: committing a saturated stretch through
    the vectorized bulk path must produce the same bits as serving every
    event through the scalar loop — for pointer mode (chunked) and heap
    mode (priority) alike.  The merged chained-cumsum time arithmetic is
    what makes this exact; a tolerance here would hide regressions."""
    flows, base = [], 0
    rng = np.random.default_rng(11)
    for j in range(6):
        ready = np.sort(rng.uniform(0.0, 0.02, 12))
        buckets = [(float(t), float(sz), 1) for t, sz in
                   zip(ready, rng.uniform(1e6, 5e7, 12))]
        plan = lower_buckets(buckets, scheduler=scheduler, n_chunks=24)
        fl = plan_to_flows(plan, _LinearCost(), 1e-6, job=f"j{j}",
                           op_id_base=base)
        base += len(fl)
        flows.extend(fl)
    assert len(flows) > 1000        # far above the small-plan threshold
    with_bulk = run_flows(flows)
    scalar = _bulk_disabled(monkeypatch, flows)
    assert with_bulk == scalar
    assert any(r.contended for r in with_bulk)


def test_bulk_commit_bit_identical_with_jitter(monkeypatch):
    flows = _priority_plan_flows(8, 10, 16, seed=3, jitter=0.005)
    assert run_flows(flows) == _bulk_disabled(monkeypatch, flows)


def test_numpy_setup_bit_identical_to_small_setup_on_bulk_workload(
        monkeypatch):
    """Small-plan (plain lists, never bulk) vs columnar (numpy + bulk)
    setups on a workload where bulk genuinely engages: with the chained
    bulk arithmetic the two paths are bit-identical end to end."""
    import repro.core.events as ev
    flows = _priority_plan_flows(6, 8, 16, seed=9)
    numpy_path = run_flows(flows)
    monkeypatch.setattr(ev, "_SMALL_PLAN_MAX_FLOWS", 10**9)
    small_path = run_flows(flows)
    monkeypatch.undo()
    assert numpy_path == small_path


# ---------------------------------------------------------------------------
# multi-rail links: per-rail clocks vs reference with one link per rail
# ---------------------------------------------------------------------------

def _with_rails(flows, n_rails, rng):
    return [f._replace(rail=int(rng.integers(0, n_rails)),
                       job=f"{f.job}@r{int(rng.integers(0, n_rails))}")
            for f in flows]


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 120), n_jobs=st.integers(1, 4),
       n_rails=st.integers(2, 4), seed=st.integers(0, 10_000))
def test_rails_equal_reference_with_link_per_rail(n, n_jobs, n_rails, seed):
    """A LinkSet of r rails must behave exactly like r independently named
    links: run the same flows through the seed engine with the rail mangled
    into the link name."""
    rng = np.random.default_rng(seed ^ 0xA5A5)
    flows = _with_rails(_random_flows(n, n_jobs, 1, seed), n_rails, rng)
    try:
        ref = run_reference_flows(
            [f._replace(link=f"{f.link}#r{f.rail}") for f in flows],
            max_iters_factor=200)
    except RuntimeError:
        pytest.skip("seed engine did not converge on this input")
    new = run_flows(flows, rails={"l0": n_rails})
    for a, b in zip(ref, new):
        scale = max(abs(a.end), abs(b.end), 1e-9)
        assert abs(a.start - b.start) <= 1e-9 * scale + 1e-15
        assert abs(a.wire_end - b.wire_end) <= 1e-9 * scale + 1e-15
        assert abs(a.end - b.end) <= 1e-9 * scale + 1e-15
        assert a.contended == b.contended


def test_rails_one_is_bit_identical_to_no_rails():
    flows = _random_flows(50, 3, 2, seed=11)
    assert run_flows(flows) == run_flows(flows, rails={"l0": 1, "l1": 1})


def test_rails_reference_equivalence_known_seeds():
    """Deterministic twin of the property test above (runs without
    hypothesis): rails == independently named links, on seeds that do
    produce cross-job rail contention.  The 120-flow cases exceed
    ``_SMALL_PLAN_MAX_FLOWS``, so the columnar numpy setup (and its rails
    routing) is exercised too, not just the small-plan path."""
    for n, seed in ((48, 2), (48, 13), (120, 99), (120, 7)):
        rng = np.random.default_rng(seed)
        flows = _with_rails(_random_flows(n, 3, 1, seed, hold_p=0.2),
                            2, rng)
        ref = run_reference_flows(
            [f._replace(link=f"{f.link}#r{f.rail}") for f in flows],
            max_iters_factor=200)
        new = run_flows(flows, rails={"l0": 2})
        assert any(r.contended for r in new)
        for a, b in zip(ref, new):
            scale = max(abs(a.end), abs(b.end), 1e-9)
            assert abs(a.end - b.end) <= 1e-9 * scale + 1e-15
            assert a.contended == b.contended


def test_rails_heavy_contention_bulk_path():
    """Rails under the bulk-commit regime: 6 jobs x 24-chunk bursts whose
    per-rail lanes saturate one LinkSet — far above the small-plan
    threshold, so the numpy setup, completion spin, and bulk commit all
    run with per-rail clocks.  Totals must match the reference engine."""
    flows = []
    base = 0
    for j in range(6):
        for b in range(12):
            for c in range(24):
                rail = (b + c) % 2
                flows.append(FlowSpec(
                    op_id=base, ready=0.01 * b, work=1e-4, latency=1e-5,
                    priority=float(b), job=f"job{j}@r{rail}", rail=rail))
                base += 1
    new = run_flows(flows, rails={"nic": 2})
    ref = run_reference_flows(
        [f._replace(link=f"{f.link}#r{f.rail}") for f in flows],
        max_iters_factor=200)
    assert len(new) == len(flows)
    for a, b in zip(ref, new):
        scale = max(abs(a.end), abs(b.end), 1e-9)
        assert abs(a.end - b.end) <= 1e-9 * scale + 1e-15


def test_rails_isolate_contention():
    """Two jobs whose flows sit on different rails of one named link never
    contend; forced onto the same rail they must."""
    mk = lambda rail: [FlowSpec(op_id=i + rail * 10, ready=0.0, work=0.5,
                                job=f"j{rail}", rail=rail)
                       for i in range(3)]
    split = run_flows(mk(0) + mk(1), rails={"nic": 2})
    assert not any(r.contended for r in split)
    same = run_flows([f._replace(rail=0) for f in mk(0) + mk(1)],
                     rails={"nic": 2})
    assert all(r.contended for r in same)
    # rails are 1/n links: a lone flow still runs at the rail's full rate
    assert split[0].wire_end == 0.5


# ---------------------------------------------------------------------------
# small-plan setup path vs the columnar numpy path (same engine, same bits)
# ---------------------------------------------------------------------------

def test_small_plan_setup_bit_identical_to_numpy_setup(monkeypatch):
    import repro.core.events as ev
    for seed in (1, 7, 42):
        flows = _random_flows(40, 4, 2, seed, dup_ready=seed == 7)
        small = run_flows(flows)
        monkeypatch.setattr(ev, "_SMALL_PLAN_MAX_FLOWS", 0)
        numpy_path = run_flows(flows)
        monkeypatch.undo()
        assert small == numpy_path


# ---------------------------------------------------------------------------
# seeded straggler perturbation (jitter axis)
# ---------------------------------------------------------------------------

def test_perturb_flows_deterministic_and_seed_sensitive():
    from repro.core.events import perturb_flows
    flows = _random_flows(30, 2, 1, seed=3)
    a = perturb_flows(flows, 0.01, seed=123)
    b = perturb_flows(flows, 0.01, seed=123)
    assert a == b, "same seed must reproduce bit-identical delays"
    c = perturb_flows(flows, 0.01, seed=124)
    assert a != c, "different seeds must perturb differently"
    d = perturb_flows(flows, 0.01, seed=123, stream=1)
    assert a != d, "streams (contention jobs) must straggle independently"
    assert all(p.ready >= f.ready for f, p in zip(flows, a))
    assert a[0]._replace(ready=flows[0].ready) == flows[0]  # only ready moves


def test_perturb_flows_zero_jitter_is_identity():
    from repro.core.events import perturb_flows
    flows = _random_flows(10, 1, 1, seed=5)
    out = perturb_flows(flows, 0.0, seed=9)
    assert out == flows
    assert out[0] is flows[0], "zero jitter must not rebuild flows"


def test_perturb_flows_linear_in_jitter():
    """Delays scale linearly with the jitter mean at fixed seed — the
    property the straggler grid's monotonicity validator rests on."""
    from repro.core.events import perturb_flows
    flows = _random_flows(20, 1, 1, seed=8)
    d1 = [p.ready - f.ready for f, p in zip(flows,
                                            perturb_flows(flows, 0.5, 77))]
    d2 = [p.ready - f.ready for f, p in zip(flows,
                                            perturb_flows(flows, 1.0, 77))]
    assert all(abs(b - 2 * a) <= 1e-12 * max(b, 1.0)
               for a, b in zip(d1, d2))
    assert all(b >= a for a, b in zip(d1, d2))


# ---------------------------------------------------------------------------
# the closed-form fifo fast path vs the engine (bit-exact dispatch)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(24, 120), seed=st.integers(0, 10_000))
def test_fifo_fast_path_bit_exact_vs_engine(n, seed):
    from repro.core.simulator import _fifo_fast_results
    rng = np.random.default_rng(seed)
    ready = np.sort(rng.uniform(0, 0.5, n))
    buckets = [(float(r), float(rng.uniform(1e3, 1e8)), 1) for r in ready]
    plan = lower_buckets(buckets, scheduler="fifo")

    class _Cost:
        def time(self, size):
            return size / 1e9 + 1e-4

        def wire_time(self, size):
            return size / 1e9

    flows = plan_to_flows(plan, _Cost(), 1e-6)
    fast = _fifo_fast_results(plan, flows)
    assert fast is not None, "eligible fifo plan must dispatch"
    slow = run_flows(flows)
    for a, b in zip(fast, slow):
        assert a.start == b.start
        assert a.wire_end == b.wire_end
        assert a.end == b.end
        assert not a.contended and not b.contended


def test_fast_path_dispatch_is_checked_not_assumed():
    from repro.core.simulator import _fifo_fast_results
    buckets = [(0.001 * i, 1e6, 1) for i in range(30)]
    fifo = lower_buckets(buckets, scheduler="fifo")

    class _Cost:
        def time(self, size):
            return size / 1e9

    flows = plan_to_flows(fifo, _Cost(), 0.0)
    assert _fifo_fast_results(fifo, flows) is not None
    # non-fifo plans never dispatch
    chunked = lower_buckets(buckets, scheduler="chunked", n_chunks=2)
    cflows = plan_to_flows(chunked, _Cost(), 0.0)
    assert _fifo_fast_results(chunked, cflows) is None
    # a flow that regresses the ready order invalidates the closed form
    bad = list(flows)
    bad[10] = bad[10]._replace(ready=0.5)
    assert _fifo_fast_results(fifo, bad) is None
    # as does a second job or a second link sneaking in
    bad = list(flows)
    bad[3] = bad[3]._replace(job="other")
    assert _fifo_fast_results(fifo, bad) is None
    bad = list(flows)
    bad[3] = bad[3]._replace(link="nic1")
    assert _fifo_fast_results(fifo, bad) is None
    # small plans stay on the engine (numpy overhead exceeds the calendar)
    small = lower_buckets(buckets[:4], scheduler="fifo")
    sflows = plan_to_flows(small, _Cost(), 0.0)
    assert _fifo_fast_results(small, sflows) is None


def test_serialized_closed_form_matches_python_loop():
    from repro.core.simulator import _serialized_closed_form
    rng = np.random.default_rng(123)
    for _ in range(50):
        n = int(rng.integers(1, 200))
        ready = np.sort(rng.uniform(0, 1.0, n))
        dur = rng.uniform(1e-6, 0.1, n) * 10.0 ** rng.integers(-3, 2)
        starts, ends = _serialized_closed_form(ready, dur)
        prev = 0.0
        for i in range(n):
            s = ready[i] if ready[i] > prev else prev
            e = s + dur[i]
            assert starts[i] == s         # bit-exact, not approx
            assert ends[i] == e
            prev = e


# ---------------------------------------------------------------------------
# stall detection (satellite bugfix: no iteration-count heuristic, and the
# no-progress counter resets on ANY committed work)
# ---------------------------------------------------------------------------

def test_stall_counter_resets_on_committed_work(monkeypatch):
    """Regression for the stall-detector accounting: the ``stale`` counter
    must reset on any committed work (an admission, a served completion,
    a bulk commit), so stale calendar pops interleaved with real progress
    can never accumulate toward the bound.  With resets in place, a
    heavily contended priority run keeps the high-water mark in single
    digits — so it must survive a bound tightened far below the event
    count (64 here vs ~18k events); without them, bursts of
    lazily-invalidated projections would sum across the run and trip."""
    import repro.core.events as ev
    flows = []
    base = 0
    for j in range(8):
        for b in range(18):
            for c in range(32):
                flows.append(FlowSpec(
                    op_id=base, ready=0.01 * b, work=1e-4, latency=1e-5,
                    priority=float(17 - b), job=f"job{j}"))
                base += 1
    monkeypatch.setattr(ev, "_STALL_FACTOR", 0)
    monkeypatch.setattr(ev, "_STALL_BASE", 64)
    res = run_flows(flows)
    assert len(res) == len(flows)


def test_stall_detector_still_fires(monkeypatch):
    """The tightened accounting must not lobotomize the detector: with a
    zero bound, the first genuinely stale pop (here: the superseded
    projections of a many-job admission burst) still raises."""
    import repro.core.events as ev
    flows = [FlowSpec(op_id=i, ready=0.0, work=1e-3 + i * 1e-9,
                      job=f"j{i % 400}") for i in range(800)]
    monkeypatch.setattr(ev, "_STALL_FACTOR", 0)
    monkeypatch.setattr(ev, "_STALL_BASE", 0)
    with pytest.raises(RuntimeError, match="no progress"):
        run_flows(flows)


def test_heavily_contended_multi_job_completes():
    """The seed's ``10 * n + 100`` convergence heuristic was a guess; the
    heap engine must finish any valid plan, however contended — here 8 jobs
    x 32-chunk plans with duplicate ready bursts on one link."""
    flows = []
    base = 0
    for j in range(8):
        for b in range(18):
            for c in range(32):
                flows.append(FlowSpec(
                    op_id=base, ready=0.01 * b, work=1e-4, latency=1e-5,
                    priority=float(b), job=f"job{j}"))
                base += 1
    res = run_flows(flows)
    assert len(res) == len(flows)
    assert all(r.end >= r.start for r in res)


def test_zero_work_flows_terminate():
    flows = [FlowSpec(op_id=i, ready=0.0, work=0.0, job=f"j{i % 3}")
             for i in range(50)]
    res = run_flows(flows)
    assert len(res) == 50
    assert all(r.end == 0.0 for r in res)
